"""Online-serving load generator + SLO benchmark (DESIGN.md §10).

Drives `repro.serve.ImageFilterServer` with a concurrent mixed-shape
client fleet and measures the request path end to end -- client submit to
future fulfilment -- under two submission disciplines:

  * **sequential** -- each client waits for its result before submitting
    the next request, so no coalescing is ever possible: every micro-batch
    holds one image (the no-serving-layer baseline, same machinery);
  * **coalesced**  -- each client submits its whole stream and then
    gathers, so concurrent same-bucket requests ride one (N, H, W)
    batched `apply_filter` call via the §8 batch fold.

Rows (`serve_*` prefix -> the BENCH_serve.json artifact, emitted through
the shared `benchmarks.common.emit` schema): per-discipline p50/p95/p99
latency (ms), throughput (mpix/s), the batch-occupancy histogram and
flush-trigger counts from `server.stats()`, and the coalesced-vs-
sequential speedup row the README table splices.

``--smoke`` is the `scripts/check.sh --smoke-serve` guard: coalesced
throughput must not fall below sequential, coalesced p99 must stay inside
a generous SLO bound derived from the measured sequential latency (only a
stall or a lost wakeup trips it), and a served output is spot-checked
bit-identical against the direct `apply_filter` call.
"""
from __future__ import annotations

import sys
import threading
import time

import numpy as np

from benchmarks.common import emit, percentiles, write_bench_json
from repro.filters import apply_filter
from repro.serve import ImageFilterServer, ServerConfig

#: (shape, filter) mix of the load: two buckets per shape family.
DEFAULT_MIX = (((128, 128), "gaussian5"), ((128, 128), "sobel_x"),
               ((64, 64), "gaussian3"))
SMOKE_MIX = (((48, 48), "gaussian3"), ((32, 32), "gaussian3"))


def _requests(rng, n: int, mix) -> list[tuple[np.ndarray, str]]:
    """n deterministic requests cycling through the (shape, filter) mix."""
    out = []
    for i in range(n):
        shape, filt = mix[i % len(mix)]
        out.append((rng.integers(0, 256, shape).astype(np.int32), filt))
    return out


def run_load(*, coalesce: bool, clients: int, per_client: int, mix,
             max_batch: int = 8, max_delay_ms: float = 2.0) -> dict:
    """One load run; returns latencies, throughput and server stats.

    The sequential discipline also zeroes the flush deadline: a lone
    request then dispatches immediately, so the baseline measures the raw
    request path, not an artificial `max_delay` sleep per request."""
    cfg = ServerConfig(max_batch=max_batch,
                       max_delay_ms=max_delay_ms if coalesce else 0.0,
                       max_pending=max(64, clients * per_client))
    rng = np.random.default_rng(0)
    streams = [_requests(rng, per_client, mix) for _ in range(clients)]
    latencies_ms: list[float] = []
    lat_lock = threading.Lock()

    def sequential_client(stream):
        for img, filt in stream:
            t0 = time.perf_counter()
            srv.submit(img, filt).result(300)
            dt = (time.perf_counter() - t0) * 1e3
            with lat_lock:
                latencies_ms.append(dt)

    def coalesced_client(stream):
        pending = []
        for img, filt in stream:
            pending.append((time.perf_counter(), srv.submit(img, filt)))
        for t0, fut in pending:
            fut.result(300)
            dt = (time.perf_counter() - t0) * 1e3
            with lat_lock:
                latencies_ms.append(dt)

    with ImageFilterServer(cfg) as srv:
        shapes = sorted({shape for shape, _ in mix})
        filters = sorted({filt for _, filt in mix})
        batches = sorted({1 << k for k in range(max_batch.bit_length())})
        srv.warmup(shapes, filters, batches=batches)
        body = sequential_client if not coalesce else coalesced_client
        threads = [threading.Thread(target=body, args=(s,)) for s in streams]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall_s = time.perf_counter() - t0
        stats = srv.stats()
    total_pix = sum(h * w for stream in streams for (img, _) in stream
                    for (h, w) in [img.shape])
    assert stats["served"] == clients * per_client, "requests went missing"
    return {"latencies_ms": latencies_ms, "wall_s": wall_s,
            "mpix_s": total_pix / wall_s / 1e6, "stats": stats}


def _emit_run(name: str, run: dict, **extra) -> None:
    stats = run["stats"]
    mean_us = np.mean(run["latencies_ms"]) * 1e3
    occupancy = ",".join(f"{n}:{c}"
                         for n, c in sorted(stats["occupancy"].items()))
    reasons = ",".join(f"{r}:{c}"
                       for r, c in sorted(stats["flush_reasons"].items()))
    emit(name, mean_us, mpix_s=round(run["mpix_s"], 3),
         **percentiles(run["latencies_ms"]), batches=stats["batches"],
         occupancy=occupancy, flush=reasons, **extra)


def bench(*, clients: int, per_client: int, mix, max_batch: int = 8,
          max_delay_ms: float = 2.0, tag: str = "serve_") -> dict:
    """The sequential-vs-coalesced pair + the speedup row."""
    runs = {}
    for label, coalesce in (("seq", False), ("coalesced", True)):
        runs[label] = run_load(coalesce=coalesce, clients=clients,
                               per_client=per_client, mix=mix,
                               max_batch=max_batch,
                               max_delay_ms=max_delay_ms)
        _emit_run(f"{tag}{label}", runs[label], clients=clients,
                  requests=clients * per_client)
    emit(f"{tag}coalesce_speedup",
         runs["coalesced"]["mpix_s"] / runs["seq"]["mpix_s"],
         "x_vs_sequential_mpix_s")
    return runs


def _identity_spot_check(mix) -> bool:
    """A served output must be byte-for-byte the direct apply_filter call."""
    rng = np.random.default_rng(7)
    (shape, filt) = mix[0]
    imgs = [rng.integers(0, 256, shape).astype(np.int32) for _ in range(3)]
    with ImageFilterServer(ServerConfig(max_batch=4,
                                        max_delay_ms=3600_000)) as srv:
        futs = [srv.submit(im, filt) for im in imgs]
        srv.close(drain=True)
    return all((f.result(60) == np.asarray(apply_filter(im, filt))).all()
               for im, f in zip(imgs, futs))


def smoke(threshold: float = 1.0) -> int:
    """Reduced-size serving guards (scripts/check.sh --smoke-serve)."""
    rc = 0
    runs = bench(clients=4, per_client=8, mix=SMOKE_MIX, max_batch=8,
                 max_delay_ms=2.0, tag="smoke_serve_")
    speedup = runs["coalesced"]["mpix_s"] / runs["seq"]["mpix_s"]
    print(f"# smoke-serve: coalesced {speedup:.2f}x sequential mpix/s "
          f"(threshold {threshold}x)")
    if speedup < threshold:
        print("# FAIL: micro-batching is slower than sequential submission")
        rc = 1
    # SLO bound: worst case a request waits out the flush deadline plus a
    # few sequential-rate batches; 20x the measured sequential mean is far
    # above that, so only a stall/lost-wakeup regression trips this.
    seq_mean_ms = float(np.mean(runs["seq"]["latencies_ms"]))
    bound_ms = 2.0 + 20 * seq_mean_ms
    p99 = percentiles(runs["coalesced"]["latencies_ms"])["p99"]
    print(f"# smoke-serve: coalesced p99 {p99:.1f} ms "
          f"(bound {bound_ms:.1f} ms)")
    if p99 > bound_ms:
        print("# FAIL: coalesced p99 latency exceeds the SLO bound")
        rc = 1
    occ = runs["coalesced"]["stats"]["occupancy"]
    if max(occ) <= 1:
        print(f"# FAIL: coalesced run never batched (occupancy {occ})")
        rc = 1
    if not _identity_spot_check(SMOKE_MIX):
        print("# FAIL: served output differs from direct apply_filter")
        rc = 1
    else:
        print("# smoke-serve: served == direct apply_filter (bit-identical)")
    return rc


def main() -> None:
    bench(clients=4, per_client=16, mix=DEFAULT_MIX, max_batch=8,
          max_delay_ms=2.0)


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        sys.exit(smoke())
    main()
    write_bench_json("BENCH_serve.json", prefix="serve_")
