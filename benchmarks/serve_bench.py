"""Online-serving load generator + SLO benchmark (DESIGN.md §10).

Drives `repro.serve.ImageFilterServer` with a concurrent mixed-shape
client fleet and measures the request path end to end -- client submit to
future fulfilment -- under two submission disciplines:

  * **sequential** -- each client waits for its result before submitting
    the next request, so no coalescing is ever possible: every micro-batch
    holds one image (the no-serving-layer baseline, same machinery);
  * **coalesced**  -- each client submits its whole stream and then
    gathers, so concurrent same-bucket requests ride one (N, H, W)
    batched `apply_filter` call via the §8 batch fold.

Rows (`serve_*` prefix -> the BENCH_serve.json artifact, emitted through
the shared `benchmarks.common.emit` schema): per-discipline p50/p95/p99
latency (ms), throughput (mpix/s), the batch-occupancy histogram and
flush-trigger counts from `server.stats()`, and the coalesced-vs-
sequential speedup row the README table splices.

``--smoke`` is the `scripts/check.sh --smoke-serve` guard: coalesced
throughput must not fall below sequential, coalesced p99 must stay inside
a generous SLO bound derived from the measured sequential latency (only a
stall or a lost wakeup trips it), and a served output is spot-checked
bit-identical against the direct `apply_filter` call.

The fault-rate scenario (DESIGN.md §12) re-runs the coalesced load with
~1% of requests deterministically poisoned through the injection harness
(`repro.runtime.fault`): the `serve_fault_clean` / `serve_fault_injected`
rows measure throughput and tail latency with the bisection-isolation
machinery actually firing, and `serve_fault_overhead` is the clean-vs-
injected throughput ratio -- the price of isolating a poisoned request
(at most 2*log2(N) extra dispatches each). ``--smoke-fault`` is the
`scripts/check.sh --smoke-fault` guard over the same machinery: isolate a
poisoned request (neighbors bit-identical), shed an expired deadline
without burning a dispatch, resume a half-journaled stream to the exact
cold-run bytes, and end with a drained server reporting healthy.

The service-level scenario (DESIGN.md §13) runs an **overload**: offered
load far above the weighted admission bound, a mixed priority cycle
(half the traffic low-priority, high-priority requests carrying a tight
`slo_ms`), and `overload_shed=True` so blocked admissions sweep queued
low-priority work instead of stalling everyone. The same load runs twice
-- static flush policy vs `adaptive=True` -- into the
`serve_slo_static` / `serve_slo_adaptive` rows (per-priority p50/p95/p99,
shed/rejected counts, the controller's chosen flush sizes) and the
`serve_slo_high_p99_gain` ratio row: how much of the high-priority tail
the SLO-aware controller claws back from the throughput-tuned static
deadline. ``--smoke-slo`` is the `scripts/check.sh --smoke-slo` guard:
under overload the highest priority class is never shed, the adaptive
high-priority p99 must fit the SLO bound (and beat static), aggregate
throughput must not collapse vs static, every served byte must equal the
direct `apply_filter` call, and a pool member whose scale-out mesh is
killed must drain to the survivor with zero client-visible failures.

The observability scenario (DESIGN.md §15) prices the telemetry layer:
the same coalesced load with tracing+profiling off vs on into the
`serve_obs_off` / `serve_obs_on` rows, the `serve_obs_overhead` ratio
(the <5% budget), and `serve_obs_drift` -- the mean observed-vs-roofline
dispatch drift from the traced run's per-(bucket, plan) profile table.
``--smoke-obs`` is the `scripts/check.sh --smoke-obs` guard: overhead
inside the budget, a 50-request mixed-priority load leaving a complete
well-formed trace (one terminal per request, monotone stages), stable
snapshot schema keys, and bit-identical served bytes with tracing on.
"""
from __future__ import annotations

import contextlib
import sys
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit, percentiles, write_bench_json
from repro.distribute import stream_filter
from repro.filters import apply_filter
from repro.runtime.fault import (
    SITE_EXECUTE,
    SITE_TILE,
    FaultInjector,
    InjectedFault,
    fault_scope,
)
from repro.serve import (
    PRIORITIES,
    DeadlineExceeded,
    ImageFilterServer,
    ServerConfig,
    ServerOverloaded,
    bucket_key,
)
from repro.serve.pool import rendezvous_score

#: (shape, filter) mix of the load: two buckets per shape family.
DEFAULT_MIX = (((128, 128), "gaussian5"), ((128, 128), "sobel_x"),
               ((64, 64), "gaussian3"))
SMOKE_MIX = (((48, 48), "gaussian3"), ((32, 32), "gaussian3"))


def _requests(rng, n: int, mix) -> list[tuple[np.ndarray, str]]:
    """n deterministic requests cycling through the (shape, filter) mix."""
    out = []
    for i in range(n):
        shape, filt = mix[i % len(mix)]
        out.append((rng.integers(0, 256, shape).astype(np.int32), filt))
    return out


def run_load(*, coalesce: bool, clients: int, per_client: int, mix,
             max_batch: int = 8, max_delay_ms: float = 2.0,
             poison_seqs: frozenset = frozenset(),
             obs: bool = False) -> dict:
    """One load run; returns latencies, throughput and server stats.

    The sequential discipline also zeroes the flush deadline: a lone
    request then dispatches immediately, so the baseline measures the raw
    request path, not an artificial `max_delay` sleep per request.

    `poison_seqs` (§12 fault scenario) names submission sequence numbers
    to deterministically poison through the injection harness: those
    requests fail with `InjectedFault` (clients tolerate it; latencies
    record successes only) while bisection re-serves every neighbor."""
    cfg = ServerConfig(max_batch=max_batch,
                       max_delay_ms=max_delay_ms if coalesce else 0.0,
                       max_pending=max(64, clients * per_client),
                       trace=bool(obs))
    rng = np.random.default_rng(0)
    streams = [_requests(rng, per_client, mix) for _ in range(clients)]
    latencies_ms: list[float] = []
    lat_lock = threading.Lock()

    def sequential_client(stream):
        for img, filt in stream:
            t0 = time.perf_counter()
            try:
                srv.submit(img, filt).result(300)
            except InjectedFault:
                continue                    # the poisoned request's fate
            dt = (time.perf_counter() - t0) * 1e3
            with lat_lock:
                latencies_ms.append(dt)

    def coalesced_client(stream):
        pending = []
        for img, filt in stream:
            pending.append((time.perf_counter(), srv.submit(img, filt)))
        for t0, fut in pending:
            try:
                fut.result(300)
            except InjectedFault:
                continue
            dt = (time.perf_counter() - t0) * 1e3
            with lat_lock:
                latencies_ms.append(dt)

    scope = contextlib.nullcontext()
    if poison_seqs:
        scope = fault_scope(FaultInjector().poison(SITE_EXECUTE,
                                                   *poison_seqs))
    with scope, ImageFilterServer(cfg) as srv:
        shapes = sorted({shape for shape, _ in mix})
        filters = sorted({filt for _, filt in mix})
        batches = sorted({1 << k for k in range(max_batch.bit_length())})
        srv.warmup(shapes, filters, batches=batches)
        body = sequential_client if not coalesce else coalesced_client
        threads = [threading.Thread(target=body, args=(s,)) for s in streams]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall_s = time.perf_counter() - t0
        stats = srv.stats()
        trace_summary = srv.trace.summary() if obs else None
    total = clients * per_client
    total_pix = sum(h * w for stream in streams for (img, _) in stream
                    for (h, w) in [img.shape])
    expect_fail = sum(1 for s in poison_seqs if s <= total)
    assert stats["served"] == total - expect_fail, "requests went missing"
    assert stats["failed"] == expect_fail, "innocent requests failed"
    served_pix = total_pix * stats["served"] / total
    return {"latencies_ms": latencies_ms, "wall_s": wall_s,
            "mpix_s": served_pix / wall_s / 1e6, "stats": stats,
            "trace": trace_summary}


def _emit_run(name: str, run: dict, **extra) -> None:
    stats = run["stats"]
    mean_us = np.mean(run["latencies_ms"]) * 1e3
    occupancy = ",".join(f"{n}:{c}"
                         for n, c in sorted(stats["occupancy"].items()))
    reasons = ",".join(f"{r}:{c}"
                       for r, c in sorted(stats["flush_reasons"].items()))
    emit(name, mean_us, mpix_s=round(run["mpix_s"], 3),
         **percentiles(run["latencies_ms"]), batches=stats["batches"],
         occupancy=occupancy, flush=reasons, **extra)


def bench(*, clients: int, per_client: int, mix, max_batch: int = 8,
          max_delay_ms: float = 2.0, tag: str = "serve_") -> dict:
    """The sequential-vs-coalesced pair + the speedup row."""
    runs = {}
    for label, coalesce in (("seq", False), ("coalesced", True)):
        runs[label] = run_load(coalesce=coalesce, clients=clients,
                               per_client=per_client, mix=mix,
                               max_batch=max_batch,
                               max_delay_ms=max_delay_ms)
        _emit_run(f"{tag}{label}", runs[label], clients=clients,
                  requests=clients * per_client)
    emit(f"{tag}coalesce_speedup",
         runs["coalesced"]["mpix_s"] / runs["seq"]["mpix_s"],
         "x_vs_sequential_mpix_s")
    return runs


def bench_fault(*, clients: int = 4, per_client: int = 25, mix=DEFAULT_MIX,
                max_batch: int = 8, max_delay_ms: float = 2.0,
                tag: str = "serve_fault_") -> dict:
    """Coalesced throughput/tail-latency under a ~1% injected failure rate
    vs the clean run (DESIGN.md §12): every 100th submission is poisoned,
    so the bisection isolation pays its 2*log2(N)-dispatch price while
    every innocent neighbor is still served bit-identically."""
    total = clients * per_client
    poison = frozenset(range(50, total + 1, 100))
    runs = {}
    runs["clean"] = run_load(coalesce=True, clients=clients,
                             per_client=per_client, mix=mix,
                             max_batch=max_batch, max_delay_ms=max_delay_ms)
    _emit_run(f"{tag}clean", runs["clean"], requests=total)
    runs["injected"] = run_load(coalesce=True, clients=clients,
                                per_client=per_client, mix=mix,
                                max_batch=max_batch,
                                max_delay_ms=max_delay_ms,
                                poison_seqs=poison)
    st = runs["injected"]["stats"]
    _emit_run(f"{tag}injected", runs["injected"], requests=total,
              poisoned=len(poison), isolated=st["isolated"],
              retries=st["retries"])
    emit(f"{tag}overhead",
         runs["clean"]["mpix_s"] / runs["injected"]["mpix_s"],
         "x_clean_vs_injected_mpix_s")
    return runs


#: §13 overload priority cycle: half the offered load is low-priority
#: (the sheddable class), a quarter high-priority with a tight SLO.
SLO_CYCLE = ("high", "low", "normal", "low")


def run_slo_load(*, adaptive: bool, clients: int, per_client: int, mix,
                 max_batch: int = 8, max_delay_ms: float = 50.0,
                 max_pending: int = 8, slo_ms: float = 25.0,
                 check_identity: bool = False) -> dict:
    """One §13 overload run: offered load >> the weighted admission bound.

    Each client submits its whole stream (coalesced discipline) cycling
    priorities through `SLO_CYCLE`; high-priority requests carry `slo_ms`.
    `overload_shed=True`, so a blocked admission sweeps queued
    low-priority work (`ServerOverloaded` on the swept futures -- clients
    tolerate it, at the gate and on the future alike). The static flush
    deadline is deliberately throughput-tuned (long): the adaptive run
    must win the high-priority tail back from it via the SLO budget.

    Returns per-priority **post-admission** latencies (successes only;
    admission is where the §13 SLO clock starts, so this is the latency a
    flush policy can actually govern -- pre-admission blocking is the
    gate's backpressure, priced by the shed/rejected counts), throughput,
    server stats, and -- with `check_identity` -- the count of served
    outputs that differ from the direct `apply_filter` call (must be
    0)."""
    cfg = ServerConfig(max_batch=max_batch, max_delay_ms=max_delay_ms,
                       max_pending=max_pending, adaptive=adaptive,
                       overload_shed=True)
    rng = np.random.default_rng(0)
    streams = [_requests(rng, per_client, mix) for _ in range(clients)]
    lat = {p: [] for p in PRIORITIES}
    shed = {p: 0 for p in PRIORITIES}
    rejected = {p: 0 for p in PRIORITIES}
    done: list[tuple[np.ndarray, str, object]] = []   # identity check
    served_pix = [0]
    lock = threading.Lock()

    waiters: list[threading.Thread] = []

    def wait_one(t0, pri, img, filt, fut):
        # one waiter per admitted request, so dt is measured at the
        # future's actual fulfilment: a gather-in-submission-order loop
        # would charge a fast high-priority result for the time the
        # client spent blocked on an earlier slow low-priority future
        try:
            fut.result(300)
        except ServerOverloaded:
            with lock:
                shed[pri] += 1
            return
        dt = (time.perf_counter() - t0) * 1e3
        with lock:
            lat[pri].append(dt)
            served_pix[0] += img.size
            if check_identity:
                done.append((img, filt, fut))

    def client(ci, stream):
        for i, (img, filt) in enumerate(stream):
            pri = SLO_CYCLE[(ci + i) % len(SLO_CYCLE)]
            kw = {"priority": pri, "tenant": f"t{ci % 2}"}
            if pri == "high":
                kw["slo_ms"] = slo_ms
            try:
                fut = srv.submit(img, filt, **kw)
            except ServerOverloaded:
                with lock:
                    rejected[pri] += 1
                continue
            # latency clock starts at ADMISSION, like the §13 SLO clock
            # (`req.submitted`): pre-admission blocking is the gate's
            # backpressure, priced by the shed/rejected counts instead
            w = threading.Thread(target=wait_one,
                                 args=(time.perf_counter(), pri, img, filt,
                                       fut))
            w.start()
            with lock:
                waiters.append(w)

    with ImageFilterServer(cfg) as srv:
        shapes = sorted({shape for shape, _ in mix})
        filters = sorted({filt for _, filt in mix})
        batches = sorted({1 << k for k in range(max_batch.bit_length())})
        srv.warmup(shapes, filters, batches=batches, priorities=PRIORITIES)
        threads = [threading.Thread(target=client, args=(ci, s))
                   for ci, s in enumerate(streams)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for w in waiters:
            w.join()
        wall_s = time.perf_counter() - t0
        stats = srv.stats()
        mismatches = sum(
            1 for img, filt, fut in done
            if not (fut.result(60) == np.asarray(apply_filter(img, filt))).all())
    # conservation: every admitted request is served or overload-shed
    assert stats["failed"] == 0, "requests failed outright under overload"
    assert stats["served"] + stats["shed_overload"] == stats["submitted"], \
        "requests went missing"
    assert lat["high"], "no high-priority request ever succeeded"
    return {"lat_ms": lat, "shed": shed, "rejected": rejected,
            "wall_s": wall_s, "mpix_s": served_pix[0] / wall_s / 1e6,
            "stats": stats, "mismatches": mismatches}


def _emit_slo_run(name: str, run: dict, **extra) -> None:
    """One `serve_slo_*` row: mean/percentile latency **per priority
    class**, shed/rejected counts, throughput, and (adaptive runs) the
    controller's chosen-flush-size histogram + decision count."""
    st = run["stats"]
    all_ms = [d for v in run["lat_ms"].values() for d in v]
    fields = {}
    for pri in PRIORITIES:
        for k, v in percentiles(run["lat_ms"][pri]).items():
            fields[f"{pri}_{k}"] = v
    ctrl = st.get("controller")
    if ctrl:
        hist: dict[int, int] = {}
        for n in ctrl["chosen"].values():
            hist[n] = hist.get(n, 0) + 1
        fields["sizes"] = ",".join(f"{n}:{c}" for n, c in sorted(hist.items()))
        fields["slo_decisions"] = ctrl["decisions"]
    emit(name, float(np.mean(all_ms)) * 1e3,
         mpix_s=round(run["mpix_s"], 3),
         shed=st["shed_overload"], rejected=st["rejected"],
         served=st["served"], **fields, **extra)


def bench_slo(*, clients: int = 6, per_client: int = 12, mix=DEFAULT_MIX,
              max_batch: int = 8, max_delay_ms: float = 50.0,
              max_pending: int = 8, slo_ms: float = 25.0,
              tag: str = "serve_slo_") -> dict:
    """The §13 static-vs-adaptive overload pair + the tail-gain row."""
    runs = {}
    for label, adaptive in (("static", False), ("adaptive", True)):
        runs[label] = run_slo_load(adaptive=adaptive, clients=clients,
                                   per_client=per_client, mix=mix,
                                   max_batch=max_batch,
                                   max_delay_ms=max_delay_ms,
                                   max_pending=max_pending, slo_ms=slo_ms)
        _emit_slo_run(f"{tag}{label}", runs[label], clients=clients,
                      offered=clients * per_client, slo_ms=slo_ms)
    hi_p99 = {k: percentiles(r["lat_ms"]["high"])["p99"]
              for k, r in runs.items()}
    emit(f"{tag}high_p99_gain", hi_p99["static"] / hi_p99["adaptive"],
         "x_static_vs_adaptive_high_p99")
    return runs


def _identity_spot_check(mix) -> bool:
    """A served output must be byte-for-byte the direct apply_filter call."""
    rng = np.random.default_rng(7)
    (shape, filt) = mix[0]
    imgs = [rng.integers(0, 256, shape).astype(np.int32) for _ in range(3)]
    with ImageFilterServer(ServerConfig(max_batch=4,
                                        max_delay_ms=3600_000)) as srv:
        futs = [srv.submit(im, filt) for im in imgs]
        srv.close(drain=True)
    return all((f.result(60) == np.asarray(apply_filter(im, filt))).all()
               for im, f in zip(imgs, futs))


def smoke(threshold: float = 1.0) -> int:
    """Reduced-size serving guards (scripts/check.sh --smoke-serve)."""
    rc = 0
    runs = bench(clients=4, per_client=8, mix=SMOKE_MIX, max_batch=8,
                 max_delay_ms=2.0, tag="smoke_serve_")
    speedup = runs["coalesced"]["mpix_s"] / runs["seq"]["mpix_s"]
    print(f"# smoke-serve: coalesced {speedup:.2f}x sequential mpix/s "
          f"(threshold {threshold}x)")
    if speedup < threshold:
        print("# FAIL: micro-batching is slower than sequential submission")
        rc = 1
    # SLO bound: worst case a request waits out the flush deadline plus a
    # few sequential-rate batches; 20x the measured sequential mean is far
    # above that, so only a stall/lost-wakeup regression trips this.
    seq_mean_ms = float(np.mean(runs["seq"]["latencies_ms"]))
    bound_ms = 2.0 + 20 * seq_mean_ms
    p99 = percentiles(runs["coalesced"]["latencies_ms"])["p99"]
    print(f"# smoke-serve: coalesced p99 {p99:.1f} ms "
          f"(bound {bound_ms:.1f} ms)")
    if p99 > bound_ms:
        print("# FAIL: coalesced p99 latency exceeds the SLO bound")
        rc = 1
    occ = runs["coalesced"]["stats"]["occupancy"]
    if max(occ) <= 1:
        print(f"# FAIL: coalesced run never batched (occupancy {occ})")
        rc = 1
    if not _identity_spot_check(SMOKE_MIX):
        print("# FAIL: served output differs from direct apply_filter")
        rc = 1
    else:
        print("# smoke-serve: served == direct apply_filter (bit-identical)")
    return rc


def smoke_fault() -> int:
    """Reduced-size §12 fault guards (scripts/check.sh --smoke-fault):
    isolate a poisoned request, shed an expired deadline, resume a
    half-journaled stream bit-identically, end healthy and drained."""
    rc = 0
    rng = np.random.default_rng(11)
    far = 3600_000.0

    # -- guard 1: a poisoned request is isolated, neighbors bit-identical
    imgs = [rng.integers(0, 256, (32, 32)).astype(np.int32)
            for _ in range(5)]
    inj = FaultInjector().poison(SITE_EXECUTE, 3)
    cfg = ServerConfig(max_batch=5, max_delay_ms=far)
    with fault_scope(inj), ImageFilterServer(cfg) as srv:
        futs = [srv.submit(im, "gaussian3") for im in imgs]
        srv.close(drain=True)
        stats = srv.stats()
    ok = stats["isolated"] == 1 and stats["served"] == 4
    for i, (im, fut) in enumerate(zip(imgs, futs)):
        if i == 2:
            ok &= fut.failed() and isinstance(fut.exception(), InjectedFault)
        else:
            ok &= (fut.result(60)
                   == np.asarray(apply_filter(im, "gaussian3"))).all()
    ok &= stats["healthy"]          # isolation is not degradation
    print(f"# smoke-fault: poisoned request isolated "
          f"(isolated={stats['isolated']}, retries={stats['retries']}, "
          f"neighbors bit-identical: {bool(ok)})")
    if not ok:
        print("# FAIL: bisection isolation lost or corrupted a neighbor")
        rc = 1

    # -- guard 2: an expired deadline sheds without burning a dispatch
    with ImageFilterServer(ServerConfig(max_batch=8,
                                        max_delay_ms=far)) as srv:
        fut = srv.submit(imgs[0], "gaussian3", deadline_ms=0.0)
        try:
            fut.result(60)
            shed_ok = False
        except DeadlineExceeded:
            shed_ok = True
        stats = srv.stats()
    shed_ok &= stats["shed"] == 1 and stats["batches"] == 0
    print(f"# smoke-fault: expired deadline shed pre-dispatch "
          f"(shed={stats['shed']}, batches={stats['batches']})")
    if not shed_ok:
        print("# FAIL: expired request was dispatched or not shed")
        rc = 1

    # -- guard 3: killed-then-resumed stream == cold run, byte for byte
    src = rng.integers(0, 256, (48, 48)).astype(np.int32)
    cold = np.asarray(stream_filter(src, "gaussian3", tile=(16, 16),
                                    tile_batch=2))
    with tempfile.TemporaryDirectory() as td:
        out = np.memmap(Path(td) / "o.u8", np.uint8, "w+", shape=src.shape)
        kill = FaultInjector().at_index(SITE_TILE, 5)
        try:
            with fault_scope(kill):
                stream_filter(src, "gaussian3", tile=(16, 16), tile_batch=2,
                              out=out)
            resume_ok = False           # the injected crash never happened
        except InjectedFault:
            res = stream_filter(src, "gaussian3", tile=(16, 16),
                                tile_batch=2, out=out, resume=True)
            resume_ok = np.array_equal(np.asarray(res), cold)
    print(f"# smoke-fault: half-journaled stream resumed bit-identically "
          f"({resume_ok})")
    if not resume_ok:
        print("# FAIL: resumed stream differs from the cold run")
        rc = 1

    # -- guard 4: after the chaos, a fresh drained server reports healthy
    with ImageFilterServer(ServerConfig(max_batch=4,
                                        max_delay_ms=far)) as srv:
        futs = [srv.submit(im, "gaussian3") for im in imgs[:4]]
        srv.close(drain=True)
        stats = srv.stats()
    end_ok = (stats["state"] == "healthy" and stats["pending"] == 0
              and stats["served"] == 4 and all(not f.failed() for f in futs))
    print(f"# smoke-fault: drained end state {stats['state']} "
          f"(pending={stats['pending']}, served={stats['served']})")
    if not end_ok:
        print("# FAIL: server did not end drained and healthy")
        rc = 1
    return rc


def smoke_slo() -> int:
    """Reduced-size §13 service-level guards (scripts/check.sh
    --smoke-slo): under overload the high class is never shed, the
    adaptive controller holds the high-priority tail inside the SLO (and
    beats the throughput-tuned static deadline) without collapsing
    throughput, every served byte equals the direct call, and a pool
    member whose scale-out mesh dies drains to the survivor with zero
    client-visible failures."""
    rc = 0
    slo_ms, max_delay_ms = 25.0, 80.0
    runs = {}
    for label, adaptive in (("static", False), ("adaptive", True)):
        runs[label] = run_slo_load(adaptive=adaptive, clients=4,
                                   per_client=8, mix=SMOKE_MIX,
                                   max_batch=8, max_delay_ms=max_delay_ms,
                                   max_pending=4, slo_ms=slo_ms,
                                   check_identity=True)

    # -- guard 1: overload engaged, and only below the top class
    for label, run in runs.items():
        pressure = run["stats"]["shed_overload"] + sum(run["rejected"].values())
        hi_dropped = run["shed"]["high"] + run["rejected"]["high"]
        print(f"# smoke-slo[{label}]: shed={run['stats']['shed_overload']} "
              f"rejected={sum(run['rejected'].values())} "
              f"high_dropped={hi_dropped}")
        if pressure == 0:
            print(f"# FAIL: {label} run never overloaded -- guard is vacuous")
            rc = 1
        if hi_dropped:
            print(f"# FAIL: {label} run dropped high-priority work")
            rc = 1

    # -- guard 2: adaptive holds the high tail inside the SLO, beats the
    # throughput-tuned static deadline, and does not collapse throughput
    hi_p99 = {k: percentiles(r["lat_ms"]["high"])["p99"]
              for k, r in runs.items()}
    bound_ms = 2 * slo_ms           # generous: controller targets slo_ms
    print(f"# smoke-slo: high p99 static {hi_p99['static']:.1f} ms vs "
          f"adaptive {hi_p99['adaptive']:.1f} ms "
          f"(slo {slo_ms:.0f} ms, bound {bound_ms:.0f} ms)")
    if hi_p99["adaptive"] > bound_ms:
        print("# FAIL: adaptive high-priority p99 blew the SLO bound")
        rc = 1
    if hi_p99["adaptive"] >= hi_p99["static"]:
        print("# FAIL: adaptive high-priority tail no better than static")
        rc = 1
    ctrl = runs["adaptive"]["stats"]["controller"]
    if ctrl["decisions"] == 0:
        print("# FAIL: the adaptive controller never saw an SLO decision")
        rc = 1
    tput = runs["adaptive"]["mpix_s"] / runs["static"]["mpix_s"]
    print(f"# smoke-slo: adaptive throughput {tput:.2f}x static "
          f"(floor 0.7x)")
    if tput < 0.7:
        print("# FAIL: SLO-aware batching collapsed aggregate throughput")
        rc = 1

    # -- guard 3: every served byte equals the direct apply_filter call
    mism = {k: r["mismatches"] for k, r in runs.items()}
    print(f"# smoke-slo: served-vs-direct mismatches {mism}")
    if any(mism.values()):
        print("# FAIL: a served output differs from direct apply_filter")
        rc = 1

    # -- guard 4: a pool member whose scale-out mesh dies is drained and
    # its buckets re-rendezvous to the survivor, zero failures visible
    rng = np.random.default_rng(5)
    imgs = [rng.integers(0, 256, (32, 32)).astype(np.int32)
            for _ in range(6)]
    key = bucket_key("gaussian3", "refmlm", "auto", "sharded", 8, 32, 32,
                     "normal")
    target = max(("m0", "m1"), key=lambda m: rendezvous_score(m, key))
    inj = FaultInjector().on_key(SITE_EXECUTE,
                                 f"exec=sharded|member={target}")
    cfg = ServerConfig(max_batch=2, max_delay_ms=3600_000.0, exec="sharded",
                       pool=((0,), (0,)), degrade_after=1, drain_after=2)
    with fault_scope(inj), ImageFilterServer(cfg) as srv:
        futs = [srv.submit(im, "gaussian3") for im in imgs]
        srv.close(drain=True)
        st = srv.stats()
    pool = st["pool"]
    ok = all((f.result(60) == np.asarray(apply_filter(im, "gaussian3"))).all()
             for im, f in zip(imgs, futs))
    ok &= pool["drains"] == 1 and pool["active"] == 1
    ok &= pool["members"][target]["state"] == "dead"
    ok &= st["healthy"]
    print(f"# smoke-slo: member {target} mesh killed -> drains="
          f"{pool['drains']} active={pool['active']} "
          f"state={pool['members'][target]['state']} healthy={st['healthy']} "
          f"served bit-identically: {bool(ok)}")
    if not ok:
        print("# FAIL: pool failover lost a byte or left the member alive")
        rc = 1
    return rc



#: the §15 overhead measurement mix: realistic frame sizes, where the
#: fixed per-request tracing cost (~a dozen microseconds of event
#: appends) is priced against milliseconds of filter work -- the regime
#: the <5% budget is specified for. Tiny thumbnail mixes measure Python
#: dict-append latency, not the telemetry design.
OBS_MIX = (((256, 256), "gaussian5"),
           ((256, 256), "sobel_x"),
           ((128, 128), "gaussian3"))


def bench_obs(*, clients: int = 4, per_client: int = 16, mix=OBS_MIX,
              max_batch: int = 8, max_delay_ms: float = 2.0,
              tag: str = "serve_obs_", best_of: int = 3) -> dict:
    """The §15 observability price: the same coalesced load with tracing +
    profiling off vs on (best-of-`best_of` to damp scheduler noise), the
    `serve_obs_overhead` ratio row, and the roofline drift summary from
    the traced run's per-(bucket, plan) profile table."""
    runs = {}
    for label, obs in (("off", False), ("on", True)):
        best = None
        for _ in range(best_of):
            r = run_load(coalesce=True, clients=clients,
                         per_client=per_client, mix=mix, max_batch=max_batch,
                         max_delay_ms=max_delay_ms, obs=obs)
            if best is None or r["mpix_s"] > best["mpix_s"]:
                best = r
        runs[label] = best
        _emit_run(f"{tag}{label}", best, clients=clients,
                  requests=clients * per_client)
    tr = runs["on"]["trace"]
    emit(f"{tag}overhead", runs["off"]["mpix_s"] / runs["on"]["mpix_s"],
         "x_off_vs_on_mpix_s", spans=tr["spans"],
         events=sum(tr["events"].values()))
    prof = runs["on"]["stats"].get("profile", {})
    drifts = sorted(row["drift_mean"] for row in prof.values()
                    if row.get("drift_mean"))
    if drifts:
        emit(f"{tag}drift", float(np.mean(drifts)),
             "x_observed_vs_roofline_mean", rows=len(prof),
             drift_min=round(drifts[0], 3), drift_max=round(drifts[-1], 3))
    return runs


def smoke_obs(threshold: float = 1.05, attempts: int = 3) -> int:
    """Reduced-size §15 observability guards (scripts/check.sh
    --smoke-obs): tracing+profiling costs < 5% coalesced throughput
    (best-of pairs, retried to damp noise); a 50-request mixed-priority
    mixed-tenant load leaves a complete well-formed trace (exactly one
    terminal per submitted request, stage timestamps monotone); the
    stats()/metrics snapshot schema keys stay stable; and a served byte
    is bit-identical with tracing on."""
    from repro.obs import STAGES, TERMINALS

    rc = 0
    ratio = None
    for attempt in range(attempts):
        off = max(run_load(coalesce=True, clients=4, per_client=12,
                           mix=OBS_MIX)["mpix_s"] for _ in range(2))
        on = max(run_load(coalesce=True, clients=4, per_client=12,
                          mix=OBS_MIX, obs=True)["mpix_s"]
                 for _ in range(2))
        ratio = off / on
        if ratio <= threshold:
            break
    print(f"# smoke-obs: tracing overhead {ratio:.3f}x "
          f"(bound {threshold:.2f}x, attempt {attempt + 1}/{attempts})")
    if ratio > threshold:
        print("# FAIL: observability costs more than the §15 budget")
        rc = 1

    rng = np.random.default_rng(3)
    cfg = ServerConfig(max_batch=4, max_delay_ms=2.0, trace=True)
    reqs = [(rng.integers(0, 256, (32, 24)).astype(np.int32),
             ("gaussian3", "box3", "sobel_x")[i % 3],
             PRIORITIES[i % len(PRIORITIES)], f"t{i % 2}")
            for i in range(50)]
    with ImageFilterServer(cfg) as srv:
        futs = [(img, filt, srv.submit(img, filt, priority=pri, tenant=ten))
                for img, filt, pri, ten in reqs]
        outs = [(img, filt, np.asarray(f.result(300))) for img, filt, f in futs]
        spans = srv.trace.spans()
        stats = srv.stats()
        msnap = srv.metrics.snapshot()
    ok = len(spans) == stats["submitted"] == 50
    for seq, evs in spans.items():
        names = [e["event"] for e in evs]
        ts = [e["ts"] for e in evs]
        order = [STAGES.index(n) for n in names if n in STAGES]
        ok &= (sum(n in TERMINALS for n in names) == 1
               and ts == sorted(ts) and order == sorted(order))
    print(f"# smoke-obs: {len(spans)} spans / {stats['submitted']} submitted, "
          f"every span one-terminal + monotone: {bool(ok)}")
    if not ok:
        print("# FAIL: the trace lost, duplicated or disordered a request")
        rc = 1

    stats_keys = {"submitted", "served", "failed", "shed", "shed_overload",
                  "pending", "rejected", "tenants", "batches", "occupancy",
                  "flush_reasons", "served_priority", "compile", "plan_memo",
                  "profile", "healthy", "state"}
    snap_keys = {"counters", "gauges", "histograms", "series",
                 "dropped_series"}
    schema_ok = stats_keys <= set(stats) and snap_keys == set(msnap)
    print(f"# smoke-obs: stats()/metrics snapshot schema stable: "
          f"{schema_ok}")
    if not schema_ok:
        print("# FAIL: the operator snapshot schema drifted")
        rc = 1

    mism = sum(1 for img, filt, out in outs
               if not np.array_equal(out, np.asarray(apply_filter(img, filt))))
    print(f"# smoke-obs: served-vs-direct mismatches with tracing on: {mism}")
    if mism:
        print("# FAIL: tracing perturbed served bytes")
        rc = 1
    return rc


def main() -> None:
    bench(clients=4, per_client=16, mix=DEFAULT_MIX, max_batch=8,
          max_delay_ms=2.0)
    bench_fault(clients=4, per_client=25, mix=DEFAULT_MIX)
    bench_slo(clients=6, per_client=12, mix=DEFAULT_MIX)
    bench_obs(clients=4, per_client=16)


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        sys.exit(smoke())
    if "--smoke-fault" in sys.argv[1:]:
        sys.exit(smoke_fault())
    if "--smoke-slo" in sys.argv[1:]:
        sys.exit(smoke_slo())
    if "--smoke-obs" in sys.argv[1:]:
        sys.exit(smoke_obs())
    main()
    write_bench_json("BENCH_serve.json", prefix="serve_")
