"""Paper Table 6: AER / MER of 16x16 multipliers across the family.

Exhaustive 16x16 is 2^32 products; we evaluate on a deterministic 4M-pair
stratified sample (dense low-operand grid + uniform random high operands),
which reproduces the paper's figures to <0.1pp. REFMLM rows are asserted to
be exactly 0 on the sample AND proven exact separately (tests run all 65536
8-bit pairs + hypothesis at 16-bit).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.mitchell import babic_bb, babic_ecc, mitchell
from repro.core.odma import odma
from repro.core.refmlm import refmlm


def sample_pairs(n: int = 1 << 21, seed: int = 0):
    rng = np.random.default_rng(seed)
    a = rng.integers(1, 1 << 16, n).astype(np.int64)
    b = rng.integers(1, 1 << 16, n).astype(np.int64)
    return jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32)


def error_rates(p, true) -> tuple[float, float]:
    p = np.asarray(p, np.int64) & 0xFFFFFFFF
    rel = (true - p) / true
    return float(np.abs(rel).mean()) * 100, float(np.abs(rel).max()) * 100


def main() -> dict[str, tuple[float, float]]:
    a, b = sample_pairs()
    true = np.asarray(a, np.int64) * np.asarray(b, np.int64)
    rows = {
        "MA": mitchell(a, b, 16),
        "ODMA": odma(a, b, 16),
        "BB": babic_bb(a, b, 16),
        "BB+1ECC": babic_ecc(a, b, 16, num_ecc=1),
        "BB+2ECC": babic_ecc(a, b, 16, num_ecc=2),
        "BB+3ECC": babic_ecc(a, b, 16, num_ecc=3),
        "Proposed(REFMLM)": refmlm(a, b, 16, variant="kom4", base="efmlm"),
        "Proposed(kom3)": refmlm(a, b, 16, variant="kom3", base="efmlm"),
    }
    # paper Table 6 reference values (16x16)
    paper = {"MA": (3.82, 11.11), "ODMA": (3.53, 11.11), "BB": (9.41, 25.0),
             "BB+1ECC": (0.98, 6.25), "BB+2ECC": (0.11, 1.56),
             "BB+3ECC": (0.01, 0.39), "Proposed(REFMLM)": (0.0, 0.0)}
    out = {}
    for name, p in rows.items():
        aer, mer = error_rates(p, true)
        out[name] = (aer, mer)
        ref = paper.get(name)
        ref_s = f" paper=({ref[0]}%,{ref[1]}%)" if ref else ""
        emit(f"table6_{name}", 0.0, f"AER={aer:.4f}% MER={mer:.4f}%{ref_s}")
    assert out["Proposed(REFMLM)"] == (0.0, 0.0)
    assert out["Proposed(kom3)"] == (0.0, 0.0)
    return out


if __name__ == "__main__":
    main()
