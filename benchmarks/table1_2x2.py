"""Paper Table 1: all 16 2-bit x 2-bit combinations -- RMP vs MLMP vs EFMLM.

Reproduces the table exactly: the single erroneous combination is 11x11
(MLMP=1000b vs RMP=1001b) and the correction term fixes it.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.refmlm import efmlm2, mlm2


def main() -> list[str]:
    a = jnp.arange(4, dtype=jnp.int32)[:, None] * jnp.ones((1, 4), jnp.int32)
    b = jnp.arange(4, dtype=jnp.int32)[None, :] * jnp.ones((4, 1), jnp.int32)
    rmp = a * b
    mlmp = mlm2(a, b)
    ef = efmlm2(a, b)
    rows = []
    n_err = 0
    for i in range(4):
        for j in range(4):
            err = int(rmp[i, j]) != int(mlmp[i, j])
            n_err += err
            rows.append(f"{i:02b}x{j:02b}: RMP={int(rmp[i,j]):04b} "
                        f"MLMP={int(mlmp[i,j]):04b} "
                        f"{'ERR' if err else 'ok '} EFMLM={int(ef[i,j]):04b}")
    exact = bool((ef == rmp).all())
    emit("table1_2x2", 0.0,
         f"mlm_errors={n_err}/16(expect 1: 11x11) efmlm_exact={exact}")
    assert n_err == 1 and exact
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
