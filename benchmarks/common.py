"""Shared benchmark utilities: timing + CSV emission + the machine-readable
BENCH_kernels.json artifact that tracks the perf trajectory across PRs."""
from __future__ import annotations

import json
import os
import time

import jax

#: Every emit() row of this process, in order -- the JSON writer's source.
RESULTS: list[dict] = []


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock microseconds per call (jit'd fns: post-compile)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "", **fields):
    """Record one benchmark row in the shared emit schema.

    `derived` is the legacy free-form annotation; structured facts go in
    `**fields` (key=value pairs -- exec mode, device count, mpix_s,
    exactness flags, ...). Fields fold into the printed CSV's derived
    column and ride the JSON artifact as a machine-readable `fields`
    mapping, so new row families (e.g. the distribute variants) never
    need ad-hoc JSON emission of their own.
    """
    if fields:
        tail = " ".join(f"{k}={v}" for k, v in fields.items())
        derived = f"{derived} {tail}".strip()
    row = {"name": name, "us_per_call": round(us, 1), "derived": derived}
    if fields:
        row["fields"] = fields
    RESULTS.append(row)
    print(f"{name},{us:.1f},{derived}")


def percentiles(samples, points=(50, 95, 99)) -> dict:
    """p50/p95/p99 (nearest-rank: ceil(n*p/100)-th order statistic) of a
    latency sample, as a fields mapping -- the BENCH_serve.json latency
    row schema (keys `p50`..`p99`, same unit as the samples)."""
    xs = sorted(samples)
    if not xs:
        return {f"p{p}": None for p in points}
    return {f"p{p}": round(xs[max(0, -(-len(xs) * p // 100) - 1)], 3)
            for p in points}


def bench_timestamp() -> str:
    """Artifact timestamp: the BENCH_TIMESTAMP env var when set (CI pins it
    for reproducible artifacts), else UTC now."""
    return os.environ.get("BENCH_TIMESTAMP") or time.strftime(
        "%Y-%m-%dT%H:%M:%SZ", time.gmtime())


def write_bench_json(path: str = "BENCH_kernels.json",
                     prefix: str = "kernel_") -> dict:
    """Write name -> {us_per_call, derived, timestamp} for every emitted row
    whose name starts with `prefix`; returns the written mapping."""
    ts = bench_timestamp()
    rows = {}
    for r in RESULTS:
        if not r["name"].startswith(prefix):
            continue
        rows[r["name"]] = {"us_per_call": r["us_per_call"],
                           "derived": r["derived"], "timestamp": ts}
        if "fields" in r:
            rows[r["name"]]["fields"] = r["fields"]
    with open(path, "w") as f:
        json.dump(rows, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path} ({len(rows)} rows)")
    return rows
