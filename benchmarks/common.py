"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock microseconds per call (jit'd fns: post-compile)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
