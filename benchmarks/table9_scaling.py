"""Paper Table 9: 4x4 -> 16x16 scaling economics.

Paper compares LUTs/delay of error-free BB+3ECC-extended-KOM vs iterative
BB+3ECC vs proposed-with-EC at 16x16. TPU analogue per design:
  * base-multiplier count + word adds per product (op economics),
  * us/call on a 512x512 operand tensor (vectorized),
  * exactness check.
Plus the MXU transplant rows: 4-pass schoolbook vs 3-pass Karatsuba
int8-limb matmuls (the paper's trade re-priced for a systolic array), with
their per-pass MXU economics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.karatsuba import op_counts
from repro.core.mitchell import babic_ecc
from repro.core.refmlm import refmlm
from repro.kernels.ops import limb_matmul


def main():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 1 << 16, (512, 512)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 1 << 16, (512, 512)), jnp.int32)
    true = jnp.asarray((np.asarray(a, np.int64) * np.asarray(b, np.int64))
                       & 0xFFFFFFFF, jnp.uint32)

    rows = {
        "BB3ECC_iterative16": (jax.jit(lambda x, y: babic_ecc(x, y, 16, num_ecc=3)), None),
        "Proposed_withEC_kom4": (jax.jit(lambda x, y: refmlm(x, y, 16, variant="kom4")),
                                 op_counts(16, 2, "kom4")),
        "Proposed_withEC_kom3": (jax.jit(lambda x, y: refmlm(x, y, 16, variant="kom3")),
                                 op_counts(16, 2, "kom3")),
    }
    for name, (fn, oc) in rows.items():
        us = time_fn(fn, a, b)
        p = fn(a, b)
        exact = bool((p.astype(jnp.uint32) == true).all())
        fields = dict(exact=exact)
        if oc:
            fields.update(base_mults=oc["base_mults"], adds=oc["adds"])
        emit(f"table9_{name}", us, **fields)

    # MXU transplant: wide matmul from int8 passes (3 vs 4 passes)
    af = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    bf = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    exact_mm = af @ bf
    for kar, passes in ((False, 4), (True, 3)):
        fn = lambda x, y, k=kar: limb_matmul(x, y, karatsuba=k)
        us = time_fn(fn, af, bf)
        rel = float(jnp.abs(fn(af, bf) - exact_mm).max() / jnp.abs(exact_mm).max())
        emit(f"table9_mxu_limb_{'kom3' if kar else 'schoolbook'}", us,
             mxu_passes=passes, relerr=f"{rel:.2e}")


if __name__ == "__main__":
    main()
