"""Paper Table 7: 4x4 multiplier comparison.

FPGA LUT counts / combinational delay do not transfer to TPU (DESIGN.md §2);
the analogue reported here per multiplier is:
  * AER / MER over ALL 256 4-bit pairs (exhaustive, like the paper's 134
    unique combinations),
  * op-count economics (base multiplies + word adds -- Table 9's LUT
    economics in op form),
  * measured us/call on a 256x256 tensor of 4-bit operands (vectorized
    throughput -- the TPU-meaningful "delay").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.karatsuba import op_counts
from repro.core.mitchell import babic_ecc, mitchell
from repro.core.odma import odma
from repro.core.refmlm import refmlm


def main():
    n = 1 << 4
    a = jnp.arange(n, dtype=jnp.int32)[:, None] * jnp.ones((1, n), jnp.int32)
    b = jnp.arange(n, dtype=jnp.int32)[None, :] * jnp.ones((n, 1), jnp.int32)
    true = (a * b).astype(jnp.float32)

    fns = {
        "Mitchell": lambda x, y: mitchell(x, y, 4),
        "ODMA": lambda x, y: odma(x, y, 4),
        "BB+1ECC": lambda x, y: babic_ecc(x, y, 4, num_ecc=1),
        "BB+2ECC": lambda x, y: babic_ecc(x, y, 4, num_ecc=2),
        "BB+3ECC": lambda x, y: babic_ecc(x, y, 4, num_ecc=3),
        "Proposed_noEC": lambda x, y: refmlm(x, y, 4, base="mlm"),
        "Proposed_withEC": lambda x, y: refmlm(x, y, 4, base="efmlm"),
    }
    paper_aer = {"Mitchell": 5.5185, "ODMA": 3.58515, "BB+1ECC": 0.28889,
                 "BB+2ECC": 0.0074, "BB+3ECC": 0.0, "Proposed_noEC": 1.7629,
                 "Proposed_withEC": 0.0}
    big_a = jax.random.randint(jax.random.PRNGKey(0), (256, 256), 0, 16, jnp.int32)
    big_b = jax.random.randint(jax.random.PRNGKey(1), (256, 256), 0, 16, jnp.int32)
    oc = op_counts(4, 2, "kom4")
    out = {}
    for name, fn in fns.items():
        p = fn(a, b).astype(jnp.float32)
        rel = jnp.where(true > 0, (true - p) / true, 0.0)
        aer = float(jnp.abs(rel).mean()) * 100
        mer = float(jnp.abs(rel).max()) * 100
        jfn = jax.jit(fn)
        us = time_fn(jfn, big_a, big_b)
        extra = (f" ops={oc['base_mults']}x2b+{oc['adds']}adds"
                 if name.startswith("Proposed") else "")
        emit(f"table7_{name}", us,
             f"AER={aer:.4f}% MER={mer:.3f}% paperAER={paper_aer[name]}%{extra}")
        out[name] = (aer, mer, us)
    assert out["Proposed_withEC"][0] == 0.0 and out["Proposed_withEC"][1] == 0.0
    return out


if __name__ == "__main__":
    main()
