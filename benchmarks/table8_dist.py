"""Paper Table 8: distribution of relative error rates of the 4x4
multiplier -- % of combinations in each error band, for BB / BB+1ECC /
BB+2ECC / proposed-with-EC-propagated / proposed-without-error (=BB+3ECC
column in the paper's layout)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.mitchell import babic_bb, babic_ecc
from repro.core.refmlm import refmlm

BANDS = [(0.0, 0.0), (0.0, 0.05), (0.05, 0.1), (0.1, 0.5), (0.5, 1.0)]


def band_percentages(p, true) -> list[float]:
    rel = np.where(true > 0, np.abs(true - np.asarray(p, np.float64)) / true, 0.0)
    nz = rel[true > 0]
    out = [float((nz == 0.0).mean() * 100)]
    for lo, hi in BANDS[1:]:
        out.append(float(((nz > lo) & (nz <= hi)).mean() * 100))
    return out


def main():
    n = 1 << 4
    a = jnp.arange(n, dtype=jnp.int32)[:, None] * jnp.ones((1, n), jnp.int32)
    b = jnp.arange(n, dtype=jnp.int32)[None, :] * jnp.ones((n, 1), jnp.int32)
    true = np.asarray(a * b, np.float64)
    rows = {
        "BB": babic_bb(a, b, 4),
        "BB+1ECC": babic_ecc(a, b, 4, num_ecc=1),
        "BB+2ECC": babic_ecc(a, b, 4, num_ecc=2),
        "WITH_ERROR(prop-noEC)": refmlm(a, b, 4, base="mlm"),
        "WITHOUT_ERROR(prop-EC)": refmlm(a, b, 4, base="efmlm"),
    }
    out = {}
    for name, p in rows.items():
        bands = band_percentages(p, true)
        out[name] = bands
        emit(f"table8_{name}", 0.0,
             "pct_by_band[0;(0,.05];(.05,.1];(.1,.5];(.5,1]]="
             + "/".join(f"{x:.1f}" for x in bands))
    assert out["WITHOUT_ERROR(prop-EC)"][0] == 100.0      # all-zero band
    return out


if __name__ == "__main__":
    main()
