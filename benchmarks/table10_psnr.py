"""Paper Table 10: PSNR of Gaussian-smoothed noisy fingerprint images per
multiplier, over salt&pepper noise levels 10/20/30/40%.

Faithful structure: base image -> add noise -> 3x3 Gaussian (scale 256)
convolution through the selected multiplier -> PSNR vs the BASE image.
The proposed (error-free) multiplier must match the exact-multiplier filter
bit-for-bit and therefore posts the best PSNR; the approximate baselines
(ODMA, iterative BB+3ECC in its *approximate* small-width usage as in the
paper's filter) degrade it.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.data.images import add_salt_pepper, fingerprint, psnr
from repro.kernels.ops import gaussian_filter, gaussian_kernel_3x3

MULTIPLIERS = ["exact", "refmlm", "mitchell", "odma", "mitchell_ecc3"]
NOISE = (10, 20, 30, 40)


def main():
    base = fingerprint((256, 256), seed=7)
    kern = jnp.asarray(gaussian_kernel_3x3(sigma=1.0, scale=256))
    out = {}
    for pct in NOISE:
        noisy = add_salt_pepper(base, pct, seed=11)
        corrupted_psnr = psnr(base, noisy)
        for mult in MULTIPLIERS:
            sm = gaussian_filter(jnp.asarray(noisy.astype(np.int32)), kern,
                                 method=mult)
            val = psnr(base, np.asarray(sm))
            out[(pct, mult)] = val
            emit(f"table10_noise{pct}_{mult}", 0.0,
                 f"psnr_corrupted={corrupted_psnr:.2f}dB psnr_smoothed={val:.2f}dB")
    for pct in NOISE:
        # error-free REFMLM == exact filter (the paper's central claim)
        assert out[(pct, "refmlm")] == out[(pct, "exact")]
        # and beats the approximate baselines
        assert out[(pct, "refmlm")] >= out[(pct, "mitchell")]
        assert out[(pct, "refmlm")] >= out[(pct, "odma")]
    return out


if __name__ == "__main__":
    main()
