"""Paper Table 10 + the filter-bank extension: PSNR per (filter, multiplier).

Part 1 is the paper's own experiment: noisy fingerprint -> 3x3 Gaussian
(Fig. 9 scale-256 table) through each multiplier -> PSNR vs the clean base,
over salt&pepper noise levels 10/20/30/40%. The proposed (error-free)
multiplier must match the exact-multiplier filter bit-for-bit and therefore
posts the best PSNR; the approximate baselines (ODMA, iterative BB+3ECC in
its *approximate* small-width usage as in the paper's filter) degrade it.

Part 2 extends the comparison to the whole bank (repro.filters, DESIGN.md
§5) on a batched pipeline: for every (filter, multiplier) pair it reports

  * psnr_vs_base  -- denoising quality vs the clean image (smoothing
                     filters only; meaningless for derivative filters), and
  * psnr_vs_exact -- fidelity of the approximate-multiplier output vs the
                     exact-multiplier output of the same filter. REFMLM is
                     bit-identical to exact on every filter (asserted), so
                     its fidelity PSNR saturates at the measurement cap.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.refmlm_filter import CONFIG
from repro.data.images import add_salt_pepper, fingerprint, psnr
from repro.filters import apply_filter
from repro.kernels.ops import gaussian_filter, gaussian_kernel_3x3

MULTIPLIERS = ["exact", "refmlm", "mitchell", "odma", "mitchell_ecc3"]
NOISE = CONFIG.noise_levels
SMOOTHING = ("gaussian3", "gaussian5", "box3")
BANK_HW = (128, 128)        # bank sweep runs smaller: 7 filters x 5 multipliers


def paper_table10() -> dict:
    """The paper's noise-sweep experiment, unchanged."""
    base = fingerprint(CONFIG.image_hw, seed=7)
    kern = jnp.asarray(gaussian_kernel_3x3(sigma=CONFIG.sigma,
                                           scale=CONFIG.kernel_scale))
    out = {}
    for pct in NOISE:
        noisy = add_salt_pepper(base, pct, seed=11)
        corrupted_psnr = psnr(base, noisy)
        for mult in MULTIPLIERS:
            sm = gaussian_filter(jnp.asarray(noisy.astype(np.int32)), kern,
                                 method=mult)
            val = psnr(base, np.asarray(sm))
            out[(pct, mult)] = val
            emit(f"table10_noise{pct}_{mult}", 0.0,
                 f"psnr_corrupted={corrupted_psnr:.2f}dB psnr_smoothed={val:.2f}dB")
    for pct in NOISE:
        # error-free REFMLM == exact filter (the paper's central claim)
        assert out[(pct, "refmlm")] == out[(pct, "exact")]
        # and beats the approximate baselines
        assert out[(pct, "refmlm")] >= out[(pct, "mitchell")]
        assert out[(pct, "refmlm")] >= out[(pct, "odma")]
    return out


def filter_bank_sweep(noise_pct: int = 20) -> dict:
    """PSNR per (filter, multiplier) over the batched pipeline."""
    bases = np.stack([fingerprint(BANK_HW, seed=7 + i)
                      for i in range(CONFIG.batch)])
    noisy = np.stack([add_salt_pepper(b, noise_pct, seed=11 + i)
                      for i, b in enumerate(bases)])
    batch = jnp.asarray(noisy.astype(np.int32))
    out = {}
    for filt in CONFIG.filters:
        got = {mult: np.asarray(apply_filter(batch, filt, method=mult,
                                             block_rows=CONFIG.block_rows))
               for mult in MULTIPLIERS}
        for mult in MULTIPLIERS:
            fid = psnr(got["exact"], got[mult])
            parts = [f"psnr_vs_exact={fid:.2f}dB"]
            if filt in SMOOTHING:
                parts.append(f"psnr_vs_base={psnr(bases, got[mult]):.2f}dB")
            out[(filt, mult)] = fid
            emit(f"table10_bank_{filt}_{mult}", 0.0, " ".join(parts))
        # the zero-error claim, extended to every filter of the bank
        assert (got["refmlm"] == got["exact"]).all(), filt
        assert out[(filt, "refmlm")] >= out[(filt, "mitchell")], filt
    return out


def main():
    out = paper_table10()
    out.update(filter_bank_sweep())
    return out


if __name__ == "__main__":
    main()
