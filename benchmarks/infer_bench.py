"""Inference benchmark: the approximate-multiplier network datapath
(DESIGN.md §14).

``PYTHONPATH=src python -m benchmarks.infer_bench`` times the calibrated
MLP head and CNN classifier across every multiplier method and emits one
``infer_<model>_<method>`` row per point: µs per batched forward call,
derived images/s and tokens/s (logit rows x num_classes per second), and
the accuracy columns of the §14 error report (top-1 agreement vs the
exact-quantized oracle and vs the float forward, logits PSNR) -- the
Table-10-style artifact lifted from filters to networks. `benchmarks.run`
folds the rows into BENCH_infer.json.

``--smoke`` is the `scripts/check.sh --smoke-infer` guard:

  * refmlm logits must be byte-equal to the exact-quantized oracle on
    both models (the paper's zero-error theorem, end to end);
  * mitchell_ecc2 top-1 agreement vs the oracle must clear the floor;
  * inference served through `repro.serve` (coalesced, several flush
    sizes) must return bytes equal to the direct forward call.
"""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import emit, time_fn, write_bench_json
from repro.data.images import inference_batch
from repro.infer import (InferWorkload, MODELS, calibrate, error_report,
                         forward, init_params)

HW = (8, 8)
N_CAL = 4
N_EVAL = 32
METHODS = ("exact", "int8", "refmlm", "refmlm_kom3", "schoolbook_int16",
           "karatsuba_int16", "mitchell", "mitchell_ecc2", "odma")
#: --smoke top-1 agreement floor for mitchell_ecc2 (measured ~1.0 on the
#: pinned seeds; generous margin so only a real accuracy regression trips).
ECC_TOP1_FLOOR = 0.75


def build_models(hw=HW, seed: int = 1):
    models = {}
    for name, build in MODELS.items():
        g = build(hw)
        models[name] = calibrate(g, init_params(g, seed=seed),
                                 inference_batch(N_CAL, hw, seed=100))
    return models


def bench(n_eval: int = N_EVAL, methods=METHODS, tag: str = "infer_") -> dict:
    models = build_models()
    x = inference_batch(n_eval, HW, seed=0)
    out: dict[str, dict] = {}
    for name, cal in sorted(models.items()):
        rep = error_report(cal, x, tuple(methods))
        for method in methods:
            us = time_fn(lambda m=method, c=cal: forward(c, x, m),
                         iters=3, warmup=1)
            images_s = n_eval / (us / 1e6)
            tokens_s = images_s * cal.graph.num_classes
            r = rep[method]
            emit(f"{tag}{name}_{method}", us,
                 images_s=round(images_s, 1), tokens_s=round(tokens_s, 1),
                 top1_vs_oracle=round(r["top1_vs_oracle"], 3),
                 top1_vs_float=round(r["top1_vs_float"], 3),
                 psnr_db=round(r["psnr_db"], 1),
                 max_ulp=max((layer["max_ulp"] for layer in r["layers"]),
                             default=0))
            out[f"{name}_{method}"] = {"us": us, "report": r}
    return out


# -------------------------------------------------------------------- smoke
def _served_equals_direct(models, x) -> bool:
    from repro.serve import ImageFilterServer, ServerConfig
    ok = True
    for max_batch in (1, 4):
        cfg = ServerConfig(max_batch=max_batch, max_delay_ms=5.0,
                           workloads={"infer": InferWorkload(models)})
        with ImageFilterServer(cfg) as srv:
            for model in sorted(models):
                for method in ("refmlm", "mitchell_ecc2"):
                    futs = [srv.submit(x[i], model, method=method,
                                       workload="infer")
                            for i in range(len(x))]
                    served = np.stack([f.result(60) for f in futs])
                    direct = np.asarray(forward(models[model], x, method))
                    if not np.array_equal(served, direct):
                        print(f"# FAIL: served {model}/{method} flush "
                              f"{max_batch} != direct forward")
                        ok = False
    return ok


def smoke() -> int:
    """Reduced-size §14 inference guards (scripts/check.sh --smoke-infer)."""
    rc = 0
    models = build_models()
    x = inference_batch(8, HW, seed=0)
    for name, cal in sorted(models.items()):
        oracle = np.asarray(forward(cal, x, "int8"))
        refmlm = np.asarray(forward(cal, x, "refmlm"))
        if np.array_equal(oracle, refmlm):
            print(f"# smoke-infer: {name} refmlm == int8 oracle "
                  "(bit-identical logits)")
        else:
            print(f"# FAIL: {name} refmlm forward differs from the "
                  "exact-quantized oracle")
            rc = 1
        rep = error_report(cal, x, ("mitchell_ecc2",))
        top1 = rep["mitchell_ecc2"]["top1_vs_oracle"]
        print(f"# smoke-infer: {name} mitchell_ecc2 top-1 agreement "
              f"{top1:.3f} (floor {ECC_TOP1_FLOOR})")
        if top1 < ECC_TOP1_FLOOR:
            print(f"# FAIL: {name} mitchell_ecc2 agreement below the floor")
            rc = 1
    if _served_equals_direct(models, x):
        print("# smoke-infer: served inference == direct forward "
              "(byte-equal, flush sizes 1 and 4)")
    else:
        rc = 1
    return rc


def main() -> None:
    bench()


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        sys.exit(smoke())
    main()
    write_bench_json("BENCH_infer.json", prefix="infer_")
