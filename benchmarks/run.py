"""Benchmark aggregator: one module per paper table + kernel bench.

``PYTHONPATH=src python -m benchmarks.run``   prints name,us_per_call,derived
CSV for every row and exits nonzero if any table's invariant fails.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (kernel_bench, table1_2x2, table6_error, table7_4x4,
                            table8_dist, table9_scaling, table10_psnr)
    mods = [table1_2x2, table6_error, table7_4x4, table8_dist,
            table9_scaling, table10_psnr, kernel_bench]
    print("name,us_per_call,derived")
    failures = []
    for mod in mods:
        t0 = time.perf_counter()
        try:
            mod.main()
            print(f"# {mod.__name__} ok in {time.perf_counter()-t0:.1f}s")
        except Exception:                              # noqa: BLE001
            failures.append(mod.__name__)
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}")
        sys.exit(1)
    print("# all benchmark tables passed")


if __name__ == "__main__":
    main()
