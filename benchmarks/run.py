"""Benchmark aggregator: one module per paper table + kernel bench +
serving bench.

``PYTHONPATH=src python -m benchmarks.run``   prints name,us_per_call,derived
CSV for every row, writes the machine-readable perf artifacts --
BENCH_kernels.json (kernel_* rows), BENCH_serve.json (serve_* rows, the
DESIGN.md §10 serving SLO schema) and BENCH_infer.json (infer_* rows, the
DESIGN.md §14 per-method accuracy/throughput schema; see
benchmarks/common.py) -- and exits nonzero if any table's invariant fails.
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks.common import write_bench_json


def main() -> None:
    from benchmarks import (infer_bench, kernel_bench, serve_bench,
                            table1_2x2, table6_error, table7_4x4,
                            table8_dist, table9_scaling, table10_psnr)
    mods = [table1_2x2, table6_error, table7_4x4, table8_dist,
            table9_scaling, table10_psnr, kernel_bench, serve_bench,
            infer_bench]
    print("name,us_per_call,derived")
    failures = []
    for mod in mods:
        t0 = time.perf_counter()
        try:
            mod.main()
            print(f"# {mod.__name__} ok in {time.perf_counter()-t0:.1f}s")
        except Exception:                              # noqa: BLE001
            failures.append(mod.__name__)
            traceback.print_exc()
    if failures:
        # Don't refresh the perf artifact from a broken run -- a partial row
        # set would silently truncate the README table downstream.
        print(f"# FAILED: {failures} (BENCH_kernels.json/BENCH_serve.json/"
              "BENCH_infer.json not written)")
        sys.exit(1)
    write_bench_json()
    write_bench_json("BENCH_serve.json", prefix="serve_")
    write_bench_json("BENCH_infer.json", prefix="infer_")
    print("# all benchmark tables passed")


if __name__ == "__main__":
    main()
